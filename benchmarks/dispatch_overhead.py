"""Dispatch-layer overhead: trace-time selection cost per unique op
fingerprint.

The GemmOp redesign adds fingerprint construction + op-keyed memoisation in
front of the paper's DB -> sieve -> cost-model pipeline. Selection runs at
*trace* time only, but trace time is what the dry-run/compile loop pays, so
we track it: legacy 2-D ``select(m, n, k)`` vs. the full ``select_op``
path (plain / grouped / epilogue-fused fingerprints), cold (first sight of
a fingerprint) and cached (memoised repeat)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import csv_row, tuned_db
from repro.core.op import Epilogue, GemmOp
from repro.core.selector import KernelSelector


def _sizes(n: int = 500):
    rng = np.random.default_rng(0)
    return [tuple(int(x) for x in row) for row in rng.integers(64, 8192, (n, 3))]


def _time_per(fn, items) -> float:
    t0 = time.perf_counter()
    for it in items:
        fn(it)
    return (time.perf_counter() - t0) / len(items) * 1e6


def run() -> List[str]:
    db = tuned_db()
    sieve = db.build_sieve()
    sizes = _sizes()
    plain_ops = [GemmOp.plain(*s) for s in sizes]
    grouped_ops = [GemmOp(m, n, k, g=8, kind="grouped") for m, n, k in sizes]
    fused_ops = [
        GemmOp.plain(m, n, k, epilogue=Epilogue(activation="gelu")) for m, n, k in sizes
    ]

    rows: List[str] = []

    # legacy 2-D path, cold then cached
    sel = KernelSelector(sieve=sieve, db=db)
    rows.append(
        csv_row(
            "dispatch.mnk_cold", _time_per(lambda s: sel.select(*s), sizes),
            "us/unique (M,N,K), DB+sieve+score",
        )
    )
    rows.append(
        csv_row(
            "dispatch.mnk_cached", _time_per(lambda s: sel.select(*s), sizes),
            "us/memoised repeat",
        )
    )

    # GemmOp path over the same shapes (fingerprint build + op-keyed lookup)
    sel2 = KernelSelector(sieve=sieve, db=db)
    rows.append(
        csv_row(
            "dispatch.op_cold", _time_per(sel2.select_op, plain_ops),
            "us/unique plain GemmOp",
        )
    )
    rows.append(
        csv_row(
            "dispatch.op_cached", _time_per(sel2.select_op, plain_ops),
            "us/memoised repeat",
        )
    )

    # grouped + fused fingerprints miss the (M,N,K)-keyed DB -> sieve/score
    sel3 = KernelSelector(sieve=sieve, db=db)
    rows.append(
        csv_row(
            "dispatch.op_grouped_cold", _time_per(sel3.select_op, grouped_ops),
            "us/unique grouped op (G=8)",
        )
    )
    rows.append(
        csv_row(
            "dispatch.op_fused_cold", _time_per(sel3.select_op, fused_ops),
            "us/unique epilogue-fused op",
        )
    )

    # fingerprint construction alone (op build + key, no selection)
    rows.append(
        csv_row(
            "dispatch.op_fingerprint",
            _time_per(lambda s: GemmOp.plain(*s).key, sizes),
            "us/GemmOp build + key",
        )
    )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="also write rows as JSON")
    args = ap.parse_args()
    rows = run()
    for row in rows:
        print(row)
    if args.json:
        import json

        from benchmarks.run import rows_to_json

        with open(args.json, "w") as f:
            json.dump(rows_to_json(rows), f, indent=2)
