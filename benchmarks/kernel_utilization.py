"""Schedule behaviour across the suite (the paper's §5.2 narrative): modeled
utilization of the best DP vs best Stream-K++ schedule per size class, plus
an interpret-mode numerical equivalence check of the actual Pallas kernels
(performance is modeled — this container has no TPU — correctness is real)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import csv_row, tuned_db
from repro.core import costmodel
from repro.core.policies import DP
from repro.core.workpart import GemmShape


def analyze() -> Dict[str, Dict[str, float]]:
    db = tuned_db()
    classes = {
        "skinny_m (M<=8)": lambda s: s[0] <= 8,
        "tall_k (K>=16384)": lambda s: s[2] >= 16384,
        "square_big (M,N>=4096)": lambda s: s[0] >= 4096 and s[1] >= 4096,
        "all": lambda s: True,
    }
    out = {}
    peak = costmodel.V5E.peak_flops / 1e12
    for name, pred in classes.items():
        dp_u, best_u, n = [], [], 0
        for size, per in db.per_policy.items():
            if not pred(size):
                continue
            n += 1
            dp_u.append(per["dp"] / peak)
            best_u.append(max(per.values()) / peak)
        if n:
            out[name] = {
                "n": n,
                "dp_util": float(np.mean(dp_u)),
                "best_util": float(np.mean(best_u)),
                "gain": float(np.mean(best_u) / max(np.mean(dp_u), 1e-12) - 1),
            }
    return out


def kernel_equivalence_check() -> float:
    """Run the real Pallas kernels (interpret) on a few suite sizes under
    their tuned winning policy; return max abs error vs the oracle."""
    import jax.numpy as jnp

    from repro.core.policies import TileConfig, policy_from_name
    from repro.core.tuner import TuningDatabase
    from repro.kernels.streamk import ops as sk_ops
    from repro.kernels.streamk.ref import gemm_ref

    db = tuned_db()
    rng = np.random.default_rng(0)
    max_err = 0.0
    small = [s for s in db.records if s[0] * s[1] <= 64 * 256 and s[2] <= 512][:4]
    for size in small:
        rec = db.records[size]
        m, n, k = size
        a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        bm, bn, bk = (int(x) for x in rec.cfg.split("x"))
        cfg = TileConfig(min(bm, 8 if m < 8 else bm), 128, 128)
        got = sk_ops.gemm(
            a, b, policy=policy_from_name(rec.policy), cfg=cfg, g=4, interpret=True
        )
        err = float(jnp.max(jnp.abs(got - gemm_ref(a, b))))
        max_err = max(max_err, err)
    return max_err


def run() -> List[str]:
    t0 = time.perf_counter()
    res = analyze()
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name, s in res.items():
        rows.append(
            csv_row(
                f"util.{name.split(' ')[0]}",
                dt_us,
                f"n={s['n']} dp={s['dp_util']:.3f} best={s['best_util']:.3f} "
                f"gain={s['gain']:+.1%}",
            )
        )
    t0 = time.perf_counter()
    err = kernel_equivalence_check()
    dt_us = (time.perf_counter() - t0) * 1e6
    rows.append(csv_row("util.kernel_equiv_maxerr", dt_us, f"{err:.2e}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
