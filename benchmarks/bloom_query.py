"""§4.2 reproduction: Bloom-filter query latency.

Paper claim: ~0.4 us per lookup on a single CPU thread. We measure the
Python implementation (per-filter single query) and the vectorised jnp
batch path (amortised per-key)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import csv_row, tuned_db
from repro.core.bloom import encode_mnk


def run() -> List[str]:
    db = tuned_db()
    sieve = db.build_sieve()
    filters = list(sieve.filters.values())
    rng = np.random.default_rng(0)
    keys = [tuple(int(x) for x in row) for row in rng.integers(1, 65536, (2000, 3))]

    # single-threaded python query across all 8 filters (a full dispatch)
    t0 = time.perf_counter()
    for m, n, k in keys:
        key = encode_mnk(m, n, k)
        for f in filters:
            key in f
    dt = time.perf_counter() - t0
    us_per_lookup = dt / (len(keys) * len(filters)) * 1e6
    us_per_dispatch = dt / len(keys) * 1e6

    # vectorised jnp batch query (all keys x all filters at once)
    import jax
    import jax.numpy as jnp

    from repro.core.jax_bloom import query_filters

    ms = jnp.asarray([k[0] for k in keys])
    ns = jnp.asarray([k[1] for k in keys])
    ks = jnp.asarray([k[2] for k in keys])
    fn = jax.jit(lambda a, b, c: query_filters(filters, a, b, c))
    fn(ms, ns, ks).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        fn(ms, ns, ks).block_until_ready()
    us_vec = (time.perf_counter() - t0) / 5 / len(keys) * 1e6

    return [
        csv_row("bloom.query_python", us_per_lookup, "us/filter-lookup (paper: ~0.4us)"),
        csv_row("bloom.query_dispatch", us_per_dispatch, "us/8-filter dispatch"),
        csv_row("bloom.query_jnp_batched", us_vec, "us/key amortised (vectorised)"),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
