"""Online adaptation: selection quality vs. requests served from a cold start.

Drives a synthetic serving trace — a zipf-weighted stream of dispatches over
``n_fingerprints`` op fingerprints the tuner has never seen (plain f32/bf16,
grouped MoE-shaped, and epilogue-fused variants) — against an initially
*empty* tuning database, with an :class:`repro.core.adaptive.AdaptiveTuner`
riding the stream exactly as ``ServeEngine(adapt_every=...)`` does.

Reported:
  * dispatches until the rolling db-hit rate first reaches 90% (convergence),
  * db-hit rate when the warmed selector replays the same trace,
  * agreement between online-committed policies and an offline ``Tuner``
    sweep of the same fingerprints (same measurement oracle -> should be 1.0),
  * trace-path and adaptation-round overheads.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import csv_row
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.op import Epilogue, GemmOp
from repro.core.selector import KernelSelector
from repro.core.tuner import Tuner, TuningDatabase


def _fingerprints(n: int, seed: int = 7) -> List[GemmOp]:
    """n distinct untuned fingerprints in the skinny-M decode regime, cycling
    through the op-space axes adaptation must cover: plain f32, plain bf16,
    grouped (MoE expert stacks), and epilogue-fused variants."""
    rng = np.random.default_rng(seed)
    variants = (
        lambda m, n_, k: GemmOp.plain(m, n_, k),
        lambda m, n_, k: GemmOp.plain(m, n_, k, in_dtype="bfloat16"),
        lambda m, n_, k: GemmOp(m, n_, k, g=8, kind="grouped"),
        lambda m, n_, k: GemmOp.plain(m, n_, k, epilogue=Epilogue(activation="gelu")),
        lambda m, n_, k: GemmOp.plain(
            m, n_, k, epilogue=Epilogue(bias=True, activation="silu")
        ),
        lambda m, n_, k: GemmOp(
            m, n_, k, g=4, kind="grouped", epilogue=Epilogue(binary="mul_silu")
        ),
    )
    ops: List[GemmOp] = []
    seen = set()
    i = 0
    while len(ops) < n:
        m = int(rng.choice([1, 2, 4, 8, 16, 32, 64]))
        nn = int(rng.choice([640, 768, 1280, 1536, 2048, 2560, 3072, 4096]))
        kk = int(rng.choice([512, 640, 896, 1024, 1792, 2048, 2816]))
        op = variants[i % len(variants)](m, nn, kk)
        i += 1
        if op.key in seen:
            continue
        seen.add(op.key)
        ops.append(op)
    return ops


def _trace(ops: List[GemmOp], dispatches: int, seed: int = 11) -> List[GemmOp]:
    """Zipf-weighted dispatch stream: a few hot fingerprints dominate, but
    the tail still repeats often enough to cross the promotion threshold."""
    rng = np.random.default_rng(seed)
    weights = 1.0 / (1.0 + np.arange(len(ops)))
    weights /= weights.sum()
    idx = rng.choice(len(ops), size=dispatches, p=weights)
    return [ops[i] for i in idx]


def run_experiment(
    n_fingerprints: int = 24,
    dispatches: int = 600,
    adapt_every: int = 16,
    window: int = 50,
    hot_threshold: int = 3,
) -> Dict:
    ops = _fingerprints(n_fingerprints)
    trace = _trace(ops, dispatches)

    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    adaptive = AdaptiveTuner(
        sel,
        config=AdaptiveConfig(
            hot_threshold=hot_threshold, max_tunes_per_step=4, rebuild_every=4
        ),
    )

    hits: List[float] = []
    convergence: Optional[int] = None
    rounds = 0
    t_trace = 0.0
    t_adapt = 0.0
    for i, op in enumerate(trace):
        t0 = time.perf_counter()
        s = sel.select_op(op)
        t_trace += time.perf_counter() - t0
        hits.append(1.0 if s.source == "tuned" else 0.0)
        if (i + 1) % adapt_every == 0:
            t0 = time.perf_counter()
            adaptive.adapt()
            t_adapt += time.perf_counter() - t0
            rounds += 1
        if (
            convergence is None
            and i + 1 >= window
            and float(np.mean(hits[-window:])) >= 0.9
        ):
            convergence = i + 1
    adaptive.drain()

    # replay the identical trace through the warmed selector
    t0 = time.perf_counter()
    replay_hits = sum(1 for op in trace if sel.select_op(op).source == "tuned")
    t_replay = time.perf_counter() - t0
    replay_rate = replay_hits / len(trace)

    # offline ground truth: the same sweep the adaptive tuner ran online
    offline = Tuner().tune(ops)
    matched = total = 0
    for key, rec in offline.records.items():
        online = db.records.get(key)
        if online is None:
            continue
        total += 1
        matched += online.policy == rec.policy
    policy_match = matched / total if total else 0.0

    return {
        "fingerprints": n_fingerprints,
        "dispatches": dispatches,
        "adapt_every": adapt_every,
        "convergence_dispatches": convergence,
        "cold_db_hit_rate": float(np.mean(hits)),
        "replay_db_hit_rate": replay_rate,
        "policy_match_offline": policy_match,
        "offline_keys_covered": total,
        "adaptations": adaptive.stats.adaptations,
        "misses": adaptive.stats.misses,
        "sieve_generation": sel.sieve_generation,
        "rebuilds": adaptive.stats.rebuilds,
        "us_per_cold_dispatch": t_trace / dispatches * 1e6,
        "us_per_adapt_round": t_adapt / max(rounds, 1) * 1e6,
        "us_per_replay_dispatch": t_replay / dispatches * 1e6,
    }


def rows_from(res: Dict) -> List[str]:
    conv = res["convergence_dispatches"]
    return [
        csv_row(
            "adapt.cold_trace",
            res["us_per_cold_dispatch"],
            f"db-hit {res['cold_db_hit_rate']:.2f} over {res['dispatches']} "
            f"cold dispatches ({res['fingerprints']} untuned fingerprints)",
        ),
        csv_row(
            "adapt.round",
            res["us_per_adapt_round"],
            f"{res['adaptations']} records committed, "
            f"sieve generation {res['sieve_generation']}",
        ),
        csv_row(
            "adapt.converged",
            float(conv) if conv is not None else float("nan"),
            "dispatches until rolling db-hit >= 90%"
            if conv is not None
            else "did not converge",
        ),
        csv_row(
            "adapt.replay",
            res["us_per_replay_dispatch"],
            f"replay db-hit {res['replay_db_hit_rate']:.3f}, "
            f"policy match vs offline sweep {res['policy_match_offline']:.2f}",
        ),
    ]


def run() -> List[str]:
    return rows_from(run_experiment())


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fingerprints", type=int, default=24)
    ap.add_argument("--dispatches", type=int, default=600)
    ap.add_argument("--adapt-every", type=int, default=16)
    ap.add_argument("--json", default=None, help="write the summary as JSON")
    args = ap.parse_args()
    res = run_experiment(
        n_fingerprints=args.fingerprints,
        dispatches=args.dispatches,
        adapt_every=args.adapt_every,
    )
    for row in rows_from(res):
        print(row)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
