"""Shared benchmark infrastructure: the tuned 923-size database (cached to
artifacts/) and timing helpers."""

from __future__ import annotations

import os
import time

from repro.configs.gemm_suite import suite
from repro.core.tuner import Tuner, TuningDatabase

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DB_PATH = os.path.join(ART, "tuning_db.json")


def tuned_db(force: bool = False) -> TuningDatabase:
    """Tune the full 923-size paper suite (cached — the one-time
    preprocessing step of §4.2)."""
    os.makedirs(ART, exist_ok=True)
    if os.path.exists(DB_PATH) and not force:
        db = TuningDatabase.load(DB_PATH)
        if len(db.records) == 923:
            return db
    db = Tuner().tune(suite())
    db.save(DB_PATH)
    return db


def time_us(fn, *args, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
