"""Shared benchmark infrastructure: the tuned 923-size database (cached to
artifacts/) and timing helpers.

Warm-start order for :func:`tuned_db`: the JSON snapshot if complete, else
replaying ``artifacts/tuning_journal.jsonl`` (the append-only artifact CI
caches keyed on the ``src/repro/core/**`` content hash — a warm CI runner
skips the full 923-size sweep entirely), else a cold sweep that *emits*
that journal so the next run is warm."""

from __future__ import annotations

import os
import time

from repro.configs.gemm_suite import suite
from repro.core.tuner import Tuner, TuningDatabase

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
DB_PATH = os.path.join(ART, "tuning_db.json")
JOURNAL_PATH = os.path.join(ART, "tuning_journal.jsonl")


def _covers_suite(db: TuningDatabase, sizes) -> bool:
    return all(tuple(s) in db.records for s in sizes)


def tuned_db(force: bool = False) -> TuningDatabase:
    """Tune the full 923-size paper suite (cached — the one-time
    preprocessing step of §4.2). Set ``REPRO_BENCH_TIMING=path`` to append
    a ``source,seconds`` line recording how the database materialised
    (cold sweep vs. snapshot/journal warm start) — CI surfaces this in the
    job summary."""
    os.makedirs(ART, exist_ok=True)
    sizes = suite()
    t0 = time.perf_counter()
    source = "cold_sweep"
    db = None
    if not force:
        if os.path.exists(DB_PATH):
            cand = TuningDatabase.load(DB_PATH)
            if _covers_suite(cand, sizes):
                db, source = cand, "snapshot"
        if db is None and os.path.exists(JOURNAL_PATH):
            cand = TuningDatabase()
            cand.replay_journal(JOURNAL_PATH, missing_ok=True)
            if _covers_suite(cand, sizes):
                db, source = cand, "journal"
                cand.save(DB_PATH)  # snapshot for the next consumer
    if db is None:
        # cold: sweep and journal as we go, so a crash keeps partial work
        # and the CI cache turns the next run into a journal warm start
        if os.path.exists(JOURNAL_PATH):
            os.remove(JOURNAL_PATH)  # stale/partial journal must not grow
        db = Tuner().tune(sizes, journal=JOURNAL_PATH)
        db.save(DB_PATH)
    timing = os.environ.get("REPRO_BENCH_TIMING")
    if timing:
        with open(timing, "a") as f:
            f.write(f"{source},{time.perf_counter() - t0:.2f}\n")
    return db


def time_us(fn, *args, warmup: int = 3, iters: int = 20) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters * 1e6


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
