"""Serving driver: batched requests with continuous batching and a KV cache,
dispatching every decode GEMM through the Stream-K++ selector (decode GEMMs
are the skinny-M regime where the paper's policies matter most — the script
prints the dispatch decisions).

Run:  PYTHONPATH=src python examples/serve_lm.py

Extra flags pass through to the launcher, e.g. low-precision serving with
fused dequant epilogues (decode GEMMs fingerprint as ``float32*int8``,
``int8*int8`` or ``float32*int4`` depending on the rung):

  PYTHONPATH=src python examples/serve_lm.py --quantize int8
  PYTHONPATH=src python examples/serve_lm.py --quantize int8-dynamic
  PYTHONPATH=src python examples/serve_lm.py --quantize int4
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    sys.argv = [
        "serve",
        "--arch", "granite-8b",
        "--preset", "100m",
        "--requests", "12",
        "--slots", "4",
        "--max-seq", "256",
        "--max-new-tokens", "16",
    ] + sys.argv[1:]
    return serve_main()


if __name__ == "__main__":
    raise SystemExit(main())
