"""Quickstart: the paper's full workflow in one script.

1. Tune Stream-K++ (policy x tile config) over a slice of the paper's
   923-size GEMM suite (ckProfiler analogue; measurement = calibrated TPU
   cost model on this CPU-only box, wall-clock on real hardware).
2. Encode the winners into per-policy Bloom filters (Open-sieve).
3. Dispatch GEMMs through the selector — exact-hit, sieve-pruned, and
   fallback paths — and run one against the actual Pallas Stream-K kernel
   in interpret mode to show numerical equivalence.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.configs.gemm_suite import suite
from repro.core import (
    ALL_POLICIES,
    GemmShape,
    KernelSelector,
    SelectorState,
    Tuner,
    gemm,
    gemm_context,
)
from repro.core.policies import TileConfig
from repro.kernels.streamk import ops as sk_ops
from repro.kernels.streamk.ref import gemm_ref


def main():
    # -- 1. tune ----------------------------------------------------------
    sizes = suite()[::24]  # ~39 sizes for a fast demo
    print(f"tuning {len(sizes)} GEMM sizes over {len(ALL_POLICIES)} policies ...")
    db = Tuner().tune(sizes)
    wins = {}
    for r in db.records.values():
        wins[r.policy] = wins.get(r.policy, 0) + 1
    print("winners by policy:", dict(sorted(wins.items())))

    # -- 2. open-sieve -------------------------------------------------------
    sieve = db.build_sieve()
    print("true-negative rate:", sieve.validate_true_negative_rate(db.winners()))
    print("filter summary:", {k: v["n_items"] for k, v in sieve.summary().items()})

    # -- 3. dispatch ---------------------------------------------------------
    sel = KernelSelector(state=SelectorState(db=db, sieve=sieve))
    with gemm_context(selector=sel) as ctx:
        for m, n, k in [sizes[0], sizes[len(sizes) // 2], (333, 555, 777)]:
            x = jnp.ones((m, k), jnp.float32)
            w = jnp.ones((k, n), jnp.float32)
            gemm(x, w, tag=f"demo{m}x{n}x{k}")
    for e in ctx.log:
        print(
            f"  {e.tag:18s} -> {e.selection.policy.name:7s}/{e.selection.cfg.name:12s}"
            f" ({e.selection.source}, pruned {e.selection.pruned} policies)"
        )
    print(
        f"selector stats: {sel.stats.lookups} lookups, elimination rate "
        f"{sel.stats.elimination_rate:.1%}"
    )

    # -- 4. the kernel itself (interpret mode on CPU) --------------------------
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(24, 384)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(384, 256)), jnp.float32)
    sel2 = sel.select(24, 256, 384)
    got = sk_ops.gemm(
        a, b, policy=sel2.policy, cfg=TileConfig(8, 128, 128), g=4, interpret=True
    )
    err = float(jnp.max(jnp.abs(got - gemm_ref(a, b))))
    print(f"pallas stream-k ({sel2.policy.name}) vs oracle: max|err| = {err:.2e}")


if __name__ == "__main__":
    main()
