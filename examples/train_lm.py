"""End-to-end training driver: ~100M-param LM for a few hundred steps with
checkpointing, fault tolerance and Stream-K++ GEMM dispatch.

This is a thin veneer over the production launcher — the same code path the
512-chip dry-run lowers — run here at 100M scale on CPU.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    # 300-step 100M runs are accelerator-scale; this CPU-only container
    # manages ~1 step/min at 100M — use --steps 300 on real hardware
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    sys.argv = [
        "train",
        "--arch", args.arch,
        "--preset", "100m",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq-len", "256",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
    ]
    return train_main()


if __name__ == "__main__":
    raise SystemExit(main())
