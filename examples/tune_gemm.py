"""The paper's tuning artifact end to end: tune the full 923-size FP16(bf16)
GEMM suite, build Open-sieve, emit the C++ header (the paper's compact
lookup-table artifact) and print the headline statistics.

Run:  PYTHONPATH=src python examples/tune_gemm.py [--out /tmp/opensieve.hpp]

Federated sweep (N workers, each tuning a disjoint shard, merged back into
the exact single-worker database):

  PYTHONPATH=src python examples/tune_gemm.py --workers 4
"""

import argparse
import os
import tempfile
import time

from repro.configs.gemm_suite import suite
from repro.core import Tuner, merge_journal_shards


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/opensieve.hpp")
    ap.add_argument("--stride", type=int, default=1, help="suite subsample stride")
    ap.add_argument(
        "--workers",
        type=int,
        default=1,
        help="shard the sweep across N simulated workers and merge journals",
    )
    args = ap.parse_args()

    sizes = suite()[:: args.stride]
    t0 = time.time()
    if args.workers > 1:
        tuner = Tuner()
        with tempfile.TemporaryDirectory() as tmp:
            paths = []
            for i in range(args.workers):
                p = os.path.join(tmp, f"shard{i}.jsonl")
                tuner.tune(sizes, shard=(i, args.workers), journal=p)
                paths.append(p)
            db, report = merge_journal_shards(paths)
        print(
            f"federated: {args.workers} worker shards merged to "
            f"{len(db.records)} records ({report.conflicts} conflicts)"
        )
    else:
        db = Tuner().tune(sizes)
    print(f"tuned {len(sizes)} sizes in {time.time() - t0:.1f}s")

    wins = {}
    for r in db.records.values():
        wins[r.policy] = wins.get(r.policy, 0) + 1
    total = len(sizes)
    sk = sum(v for k, v in wins.items() if k != "dp")
    print(f"winners: {dict(sorted(wins.items()))}")
    print(f"data-parallel optimal: {(total - sk) / total:.1%} (paper: ~87%)")
    print(f"stream-k-based optimal: {sk / total:.1%} (paper: ~13%)")

    sieve = db.build_sieve()
    print("true-negative rate:", sieve.validate_true_negative_rate(db.winners()))
    hdr = sieve.encode_cpp_header()
    with open(args.out, "w") as f:
        f.write(hdr)
    print(f"C++ header artifact: {args.out} ({len(hdr)} bytes, "
          f"{len(hdr) / max(len(sizes), 1):.0f} B/size pre-compression)")


if __name__ == "__main__":
    main()
