"""The paper's tuning artifact end to end: tune the full 923-size FP16(bf16)
GEMM suite, build Open-sieve, emit the C++ header (the paper's compact
lookup-table artifact) and print the headline statistics.

Run:  PYTHONPATH=src python examples/tune_gemm.py [--out /tmp/opensieve.hpp]
"""

import argparse
import time

from repro.configs.gemm_suite import suite
from repro.core import Tuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/opensieve.hpp")
    ap.add_argument("--stride", type=int, default=1, help="suite subsample stride")
    args = ap.parse_args()

    sizes = suite()[:: args.stride]
    t0 = time.time()
    db = Tuner().tune(sizes)
    print(f"tuned {len(sizes)} sizes in {time.time() - t0:.1f}s")

    wins = {}
    for r in db.records.values():
        wins[r.policy] = wins.get(r.policy, 0) + 1
    total = len(sizes)
    sk = sum(v for k, v in wins.items() if k != "dp")
    print(f"winners: {dict(sorted(wins.items()))}")
    print(f"data-parallel optimal: {(total - sk) / total:.1%} (paper: ~87%)")
    print(f"stream-k-based optimal: {sk / total:.1%} (paper: ~13%)")

    sieve = db.build_sieve()
    print("true-negative rate:", sieve.validate_true_negative_rate(db.winners()))
    hdr = sieve.encode_cpp_header()
    with open(args.out, "w") as f:
        f.write(hdr)
    print(f"C++ header artifact: {args.out} ({len(hdr)} bytes, "
          f"{len(hdr) / max(len(sizes), 1):.0f} B/size pre-compression)")


if __name__ == "__main__":
    main()
