"""The paper's tuning artifact end to end: tune the full 923-size FP16(bf16)
GEMM suite, build Open-sieve, emit the C++ header (the paper's compact
lookup-table artifact) and print the headline statistics.

Run:  PYTHONPATH=src python examples/tune_gemm.py [--out /tmp/opensieve.hpp]

Federated sweep (N workers, each tuning a disjoint shard, merged back into
the exact single-worker database):

  PYTHONPATH=src python examples/tune_gemm.py --workers 4

Analytical-first extras: ``--top-k 5`` measures only the cost model's top-5
ranked candidates per size (~5-10x fewer measurements than the exhaustive
sweep), ``--calibrate`` fits a CalibratedMachine from the sweep's records
(journaled with ``--journal`` so serving runs warm-start model-first
dispatch from it), and ``--mach-json`` overrides the nominal Machine
constants from a JSON field dict.
"""

import argparse
import json
import os
import tempfile
import time

from repro.configs.gemm_suite import suite
from repro.core import Tuner, merge_journal_shards
from repro.core import costmodel
from repro.core.calibrate import (
    CalibrationError,
    append_calibration,
    calibrate_db,
    machine_from_json,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="/tmp/opensieve.hpp")
    ap.add_argument("--stride", type=int, default=1, help="suite subsample stride")
    ap.add_argument(
        "--workers",
        type=int,
        default=1,
        help="shard the sweep across N simulated workers and merge journals",
    )
    ap.add_argument(
        "--journal",
        default=None,
        help="append each record (and the --calibrate fit) to this JSONL "
        "tuning journal",
    )
    ap.add_argument(
        "--top-k",
        type=int,
        default=None,
        help="budgeted sweep: measure only the cost model's top-k ranked "
        "candidates per size (default: the exhaustive oracle sweep)",
    )
    ap.add_argument(
        "--calibrate",
        action="store_true",
        help="fit a CalibratedMachine from the sweep's records and report "
        "the fitted terms (appended to --journal when set)",
    )
    ap.add_argument(
        "--mach-json",
        default=None,
        help="JSON file of Machine field overrides the sweep measures under",
    )
    args = ap.parse_args()

    mach = costmodel.V5E
    if args.mach_json:
        with open(args.mach_json) as f:
            mach = machine_from_json(json.load(f))
        print(
            f"machine overrides: peak={mach.peak_flops / 1e12:.1f} TF/s "
            f"bw={mach.hbm_bw / 1e9:.0f} GB/s lanes={mach.lanes}"
        )

    sizes = suite()[:: args.stride]
    t0 = time.time()
    tuner = Tuner(mach=mach, top_k=args.top_k)
    if args.workers > 1:
        with tempfile.TemporaryDirectory() as tmp:
            paths = []
            for i in range(args.workers):
                p = os.path.join(tmp, f"shard{i}.jsonl")
                tuner.tune(sizes, shard=(i, args.workers), journal=p)
                paths.append(p)
            db, report = merge_journal_shards(paths)
        print(
            f"federated: {args.workers} worker shards merged to "
            f"{len(db.records)} records ({report.conflicts} conflicts)"
        )
    else:
        db = tuner.tune(sizes, journal=args.journal)
    print(
        f"tuned {len(sizes)} sizes in {time.time() - t0:.1f}s "
        f"({tuner.measurements} measurements"
        + (f", top-k={args.top_k} budget)" if args.top_k else ", full sweep)")
    )

    if args.calibrate:
        try:
            db.set_calibration(calibrate_db(db, base=mach))
        except CalibrationError as e:
            print(f"calibration skipped: {e}")
        else:
            cm = db.calibration
            for pk, m in cm.profiles:
                print(
                    f"calibrated profile {pk}: peak={m.peak_flops / 1e12:.1f} "
                    f"TF/s bw={m.hbm_bw / 1e9:.0f} GB/s "
                    f"launch={m.launch_overhead_s * 1e6:.2f}us "
                    f"fixup={m.fixup_serial_s * 1e6:.2f}us"
                )
            print(
                f"calibration: {cm.n_records} records, median |rel resid| "
                f"{cm.residual:.4f}"
            )
            if args.journal:
                append_calibration(args.journal, cm)
                print(f"calibration journaled to {args.journal}")

    wins = {}
    for r in db.records.values():
        wins[r.policy] = wins.get(r.policy, 0) + 1
    total = len(sizes)
    sk = sum(v for k, v in wins.items() if k != "dp")
    print(f"winners: {dict(sorted(wins.items()))}")
    print(f"data-parallel optimal: {(total - sk) / total:.1%} (paper: ~87%)")
    print(f"stream-k-based optimal: {sk / total:.1%} (paper: ~13%)")

    sieve = db.build_sieve()
    print("true-negative rate:", sieve.validate_true_negative_rate(db.winners()))
    hdr = sieve.encode_cpp_header()
    with open(args.out, "w") as f:
        f.write(hdr)
    print(f"C++ header artifact: {args.out} ({len(hdr)} bytes, "
          f"{len(hdr) / max(len(sizes), 1):.0f} B/size pre-compression)")


if __name__ == "__main__":
    main()
